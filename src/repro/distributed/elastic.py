"""Elastic scaling: reshard a checkpoint between mesh configurations.

The stateless-launcher posture for node failures beyond checkpoint/restart:
params and optimizer state are saved as full (unsharded) host arrays by
the CheckpointManager; growing/shrinking the `data` (FSDP) axis — or
changing the mesh shape entirely — is a matter of re-deriving the
PartitionSpecs with the rules engine and re-placing the arrays.  This
module provides the placement step plus a host-side plan describing
exactly which byte ranges each device loads (what a restore server would
serve at 1000-node scale, where no single host holds the full model).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np

from repro.distributed import sharding


def replace_onto_mesh(tree: Any, mesh) -> Any:
    """Host pytree → device arrays sharded per the rules engine on `mesh`
    (works for any mesh the dims divide — the divisibility guard falls
    back to replication elsewhere)."""
    specs = sharding.param_specs(jax.eval_shape(lambda: tree), mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, specs)


def shard_plan(shape_tree: Any, mesh) -> dict[str, dict]:
    """Host-side resharding plan: for each leaf, the PartitionSpec and the
    per-device shard shape under `mesh` — lets an orchestrator compute
    which checkpoint byte-ranges each rank must fetch after an elastic
    resize, without touching devices."""
    specs = sharding.param_specs(shape_tree, mesh)
    plan = {}

    def visit(path, leaf, spec):
        name = "/".join(str(getattr(e, "key", getattr(e, "name", e)))
                        for e in path)
        pspec = spec.spec
        shard_shape = list(leaf.shape)
        for dim, ax in enumerate(tuple(pspec)):
            if ax is None:
                continue
            size = (mesh.shape[ax] if isinstance(ax, str)
                    else int(np.prod([mesh.shape[a] for a in ax])))
            shard_shape[dim] //= size
        plan[name] = {
            "global_shape": list(leaf.shape),
            "spec": str(pspec),
            "shard_shape": shard_shape,
            "bytes_per_shard": int(np.prod(shard_shape))
            * np.dtype(leaf.dtype).itemsize,
        }

    jax.tree_util.tree_map_with_path(visit, shape_tree, specs)
    return plan
