"""Elastic scaling: reshard a checkpoint between mesh configurations.

The stateless-launcher posture for node failures beyond checkpoint/restart:
params and optimizer state are saved as full (unsharded) host arrays by
the CheckpointManager; growing/shrinking the `data` (FSDP) axis — or
changing the mesh shape entirely — is a matter of re-deriving the
PartitionSpecs with the rules engine and re-placing the arrays.  This
module provides the placement step plus a host-side plan describing
exactly which byte ranges each device loads (what a restore server would
serve at 1000-node scale, where no single host holds the full model).
"""
from __future__ import annotations

import hashlib
import os
from typing import Any

import jax
import numpy as np

from repro.distributed import sharding


def replace_onto_mesh(tree: Any, mesh) -> Any:
    """Host pytree → device arrays sharded per the rules engine on `mesh`
    (works for any mesh the dims divide — the divisibility guard falls
    back to replication elsewhere)."""
    specs = sharding.param_specs(jax.eval_shape(lambda: tree), mesh)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, specs)


def shard_plan(shape_tree: Any, mesh) -> dict[str, dict]:
    """Host-side resharding plan: for each leaf, the PartitionSpec and the
    per-device shard shape under `mesh` — lets an orchestrator compute
    which checkpoint byte-ranges each rank must fetch after an elastic
    resize, without touching devices."""
    specs = sharding.param_specs(shape_tree, mesh)
    plan = {}

    def visit(path, leaf, spec):
        name = "/".join(str(getattr(e, "key", getattr(e, "name", e)))
                        for e in path)
        pspec = spec.spec
        shard_shape = list(leaf.shape)
        for dim, ax in enumerate(tuple(pspec)):
            if ax is None:
                continue
            size = (mesh.shape[ax] if isinstance(ax, str)
                    else int(np.prod([mesh.shape[a] for a in ax])))
            shard_shape[dim] //= size
        plan[name] = {
            "global_shape": list(leaf.shape),
            "spec": str(pspec),
            "shard_shape": shard_shape,
            "bytes_per_shard": int(np.prod(shard_shape))
            * np.dtype(leaf.dtype).itemsize,
        }

    jax.tree_util.tree_map_with_path(visit, shape_tree, specs)
    return plan


def party_handoff_plan(checkpoint_root: str, name: str,
                       step: int | None = None) -> dict:
    """Party-level analogue of `shard_plan` for the EFMVFL cluster: the
    exact files (and byte counts) a REPLACEMENT party must load to take
    over a quarantined party's role at an epoch boundary.

    The supervisor (`launch.cluster.train_vfl_socket_resilient`) calls
    this before admitting a standby replica: party state is durable
    only as `<root>/party_<name>/step_<n>.{npz,json}` checkpoints
    (weights, stream cursors, meter ledgers — never key material, which
    is seed-re-derived), so the handoff IS this manifest.  `step=None`
    picks the newest step that has both archive and manifest on disk;
    an empty plan (step 0, no files) means the replacement starts the
    roll-back-and-replay from scratch.
    """
    from repro.checkpoint import party_checkpoint_dir
    directory = party_checkpoint_dir(checkpoint_root, name)
    chosen, files = 0, []
    if os.path.isdir(directory):
        steps = sorted({int(f.split("_")[1].split(".")[0])
                        for f in os.listdir(directory)
                        if f.startswith("step_") and f.endswith(".json")},
                       reverse=True)
        for s in steps:
            if step is not None and s != step:
                continue
            paths = [os.path.join(directory, f"step_{s}{ext}")
                     for ext in (".npz", ".json")]
            if not all(os.path.isfile(p) for p in paths):
                continue
            chosen = s
            files = []
            for p in paths:            # integrity fingerprint per file —
                with open(p, "rb") as f:     # the replacement re-hashes
                    digest = hashlib.sha256(f.read()).hexdigest()
                files.append({"path": p, "bytes": int(os.path.getsize(p)),
                              "sha256": digest})
            break
    return {"party": name, "step": int(chosen), "files": files,
            "total_bytes": int(sum(f["bytes"] for f in files))}
