"""Secure collectives on the production mesh.

`modmul_reduce` — the homomorphic ⊕-reduction over a mesh axis.  Paillier
addition is modular *multiplication* of ciphertext residues, which psum
cannot express; this is a log2(axis)-depth ppermute ladder (recursive
halving), each rank combining with its partner via `mont_mul`.  It is the
collective the EFMVFL gradient step (pod = party) lowers to in
launch/secure_dryrun.py — DESIGN.md §3's "homomorphic reduction as a tree
collective".

`secure_allreduce_shares` — additive-share psum: each party holds an
additive share of a gradient; summing shares IS a psum, so cross-silo
secure aggregation of LM gradients (core/vfl_lm.py) maps onto the native
collective with zero overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.crypto.bigint import Modulus, mont_mul


def modmul_reduce(x: jnp.ndarray, mod: Modulus, axis_name: str,
                  axis_size: int) -> jnp.ndarray:
    """x: (..., L) Montgomery residues, one shard per rank along
    `axis_name` (power-of-two size).  Returns the ⊕-product of all ranks'
    residues, replicated (all ranks end with the same value)."""
    assert axis_size & (axis_size - 1) == 0, "power-of-two axis"
    idx = jax.lax.axis_index(axis_name)
    step = 1
    while step < axis_size:
        # exchange with the partner at distance `step` (butterfly — every
        # rank stays active, so the result ends replicated, not rooted)
        perm = [(i, i ^ step) for i in range(axis_size)]
        other = jax.lax.ppermute(x, axis_name, perm)
        x = mont_mul(x, other, mod)
        step <<= 1
    del idx
    return x


def secure_allreduce_shares(share: jnp.ndarray, axis_name: str
                            ) -> jnp.ndarray:
    """Additive-share aggregation = native psum over the party axis."""
    return jax.lax.psum(share, axis_name)


def make_modmul_reduce_shardmap(mesh, mod: Modulus, axis_name: str):
    """shard_map wrapper: (n_shards, batch, L) global → (batch, L) product
    per shard group, replicated."""
    from jax.sharding import PartitionSpec as P
    from repro.distributed.shardmap_compat import shard_map

    axis_size = mesh.shape[axis_name]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=P(axis_name, None, None),
        out_specs=P(axis_name, None, None),
        check_vma=False)
    def reduce_fn(x):
        out = modmul_reduce(x[0], mod, axis_name, axis_size)
        return out[None]

    return reduce_fn
