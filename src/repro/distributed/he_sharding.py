"""Mesh-sharded HE engine: data-parallel `shard_map` over the ciphertext
batch axis of the Paillier hot path.

PR 2 made every Paillier hot loop dispatch through one
`crypto.engine.CryptoEngine`; every one of those ops is batched over
ciphertexts (encryption-noise modexps over the batch, the Protocol-3
matvec over ciphertext rows, CRT decryption over received ciphertexts),
and the batch elements are independent group elements of Z*_{n²}.  That
makes the whole hot path data-parallel: shard the batch axis over a
device mesh, run the single-device engine per shard, and combine — for
the matvec, with the homomorphic ⊕ (`secure_ops.modmul_reduce`, the
same ppermute ladder the pod-level lowering uses).

Bit-exactness (the invariant `tests/test_he_sharding.py` pins):

* `mont_mul` / `mont_exp_bits` are row-wise independent — sharding the
  batch is a pure layout change.
* the windowed matvec's per-shard partials are exact group elements;
  group products are associative and canonical Montgomery residues are
  unique, so the butterfly ⊕-combine equals the single-device
  sequential/chunked fold bit for bit (the `ops.he_matvec_fused`
  chunking argument, lifted across devices).
* padded rows carry zero digits, which select mont(1) from the power
  table and fold to the group identity.

Entry points: `ShardedCryptoEngine` (a `CryptoEngine` whose `mesh` is
mandatory) or any `CryptoEngine` constructed with ``mesh=`` — the base
class routes its batched ops here whenever `engine.sharded` is true.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.crypto.bigint import Modulus, mont_mul as _lib_mont_mul, mont_one
from repro.crypto.engine import CryptoEngine
from repro.distributed.secure_ops import modmul_reduce
from repro.distributed.shardmap_compat import shard_map

_U32 = jnp.uint32


@dataclasses.dataclass(frozen=True)
class ShardedCryptoEngine(CryptoEngine):
    """A `CryptoEngine` that REQUIRES a device mesh.

    Identical dispatch surface to `CryptoEngine` (every batched op —
    `mont_mul`, `mont_exp_bits`, `mont_exp_const`, `he_matvec_windowed`
    and the `to_mont`/`from_mont` conveniences — accepts and returns the
    same canonical uint32 limb arrays); the batch axis is sharded over
    ``mesh.shape[mesh_axis]`` devices.  Construct with e.g.::

        mesh = jax.make_mesh((n_dev,), ("data",))
        eng = ShardedCryptoEngine(backend="jnp", mesh=mesh)

    or equivalently ``CryptoEngine(..., mesh=mesh)``; `ShardedCryptoEngine`
    only adds the constructor-time check that a mesh is present.
    """

    def __post_init__(self):
        if self.mesh is None:
            raise ValueError("ShardedCryptoEngine requires mesh=; use "
                             "CryptoEngine for the single-device path")
        if self.mesh_axis not in self.mesh.shape:
            raise ValueError(f"mesh has no axis {self.mesh_axis!r}; "
                             f"axes are {tuple(self.mesh.shape)}")
        size = self.mesh.shape[self.mesh_axis]
        if size & (size - 1):
            raise ValueError(
                f"mesh axis {self.mesh_axis!r} has size {size}; the "
                "matvec ⊕-combine (modmul_reduce butterfly) needs a "
                "power-of-two axis")


def make_sharded_engine(mesh, backend: str | None = None,
                        mesh_axis: str = "data", **kw) -> ShardedCryptoEngine:
    """Resolve `backend` like `engine.make` (env var / auto) and wrap it
    in a `ShardedCryptoEngine` over `mesh`'s `mesh_axis`."""
    from repro.crypto import engine as engine_mod
    return ShardedCryptoEngine(backend=engine_mod.resolve_backend(backend),
                               mesh=mesh, mesh_axis=mesh_axis, **kw)


# ---------------------------------------------------------------------------
# Batch-axis plumbing
# ---------------------------------------------------------------------------

def _flatten_batch(arrs, trailing_dims):
    """Broadcast leading (batch) dims across `arrs` and flatten them to
    one row axis.  `trailing_dims[i]` = number of non-batch trailing dims
    of arrs[i].  Returns (flat_arrays, batch_shape, flat_count)."""
    bshape = jnp.broadcast_shapes(*[a.shape[:a.ndim - t]
                                    for a, t in zip(arrs, trailing_dims)])
    flat = int(np.prod(bshape)) if bshape else 1
    out = []
    for a, t in zip(arrs, trailing_dims):
        tail = a.shape[a.ndim - t:]
        a = jnp.broadcast_to(a, bshape + tail)
        out.append(a.reshape((flat,) + tail))
    return out, bshape, flat


def _pad_rows(x: jnp.ndarray, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[0]) % multiple
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x


# jitted shard_map bodies, cached on (op, engine, modulus[, window]) so
# the Paillier hot path traces once per op/shape instead of per call
# (engines and meshes are hashable; Modulus is keyed by its int value)
_BODY_CACHE: dict = {}


def _rowwise_fn(engine: CryptoEngine, op: str, mod: Modulus):
    """Build (or fetch) the jitted shard_map body for a row-independent
    two-array op: (B, L)×(B, t) row shards → (B, L)."""
    key = (op, engine, mod.value)
    fn = _BODY_CACHE.get(key)
    if fn is not None:
        return fn
    inner = engine.single_device()
    mesh, axis = engine.mesh, engine.mesh_axis
    if op == "mont_mul":
        def body(a, b):
            return inner.mont_mul(a, b, mod)
    else:
        def body(a, b):
            return inner.mont_exp_bits(a, b, mod)
    fn = jax.jit(shard_map(body, mesh=mesh,
                           in_specs=(P(axis, None), P(axis, None)),
                           out_specs=P(axis, None), check_vma=False))
    _BODY_CACHE[key] = fn
    return fn


def _sharded_rowwise(engine: CryptoEngine, op: str, mod: Modulus, arrs):
    """Run a row-wise-independent batched op under shard_map: broadcast +
    flatten the batch dims, pad to the axis size, one shard per device."""
    size = engine.mesh.shape[engine.mesh_axis]
    flat_arrs, bshape, flat = _flatten_batch(arrs, (1, 1))
    padded = [_pad_rows(a, size) for a in flat_arrs]
    out = _rowwise_fn(engine, op, mod)(*padded)
    return out[:flat].reshape(bshape + (mod.L,))


# ---------------------------------------------------------------------------
# Sharded ops (called by CryptoEngine when `engine.sharded`)
# ---------------------------------------------------------------------------

def sharded_mont_mul(engine: CryptoEngine, a: jnp.ndarray, b: jnp.ndarray,
                     mod: Modulus) -> jnp.ndarray:
    """Batched Montgomery product, batch rows sharded over the mesh.
    Row-wise independent, so the result is trivially bit-exact vs the
    single-device engine."""
    a = jnp.asarray(a, _U32)
    b = jnp.asarray(b, _U32)
    return _sharded_rowwise(engine, "mont_mul", mod, (a, b))


def sharded_mont_exp_bits(engine: CryptoEngine, base: jnp.ndarray,
                          bits: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    """Batched constant-time ladder, batch rows sharded over the mesh.
    Padded rows run the ladder on zeros and are dropped on the way out."""
    base = jnp.asarray(base, _U32)
    bits = jnp.asarray(bits, _U32)
    return _sharded_rowwise(engine, "mont_exp", mod, (base, bits))


def _windowed_partial(engine: CryptoEngine, cts: jnp.ndarray,
                      digits: jnp.ndarray, mod: Modulus,
                      window: int) -> jnp.ndarray:
    """One shard's windowed matvec partial: (n_loc, L) cts ×
    (n_loc, m, levels) digits -> (m, L) partial ⊕-product.  Kernel
    backends run the fused kernel; the jnp backend runs the library
    ladder (power table + per-level tree-⊕ + `window` squarings) —
    the same group element either way."""
    if engine.uses_kernels:
        from repro.kernels import ops
        return ops.he_matvec_fused(cts, digits, mod, window=window,
                                   tile_m=engine.tile_m,
                                   chunk_n=engine.chunk_n,
                                   interpret=engine.interpret)
    n, m, levels = digits.shape
    one = mont_one(mod)
    table = [jnp.broadcast_to(one, cts.shape), cts]
    for _ in range(2, 1 << window):
        table.append(_lib_mont_mul(table[-1], cts, mod))
    table = jnp.stack(table, axis=0)                  # (2^w, n, L)
    acc = jnp.broadcast_to(one, (m, mod.L))
    for lvl in range(levels):
        for _ in range(window):
            acc = _lib_mont_mul(acc, acc, mod)
        sel = jnp.take_along_axis(
            table[:, :, None, :], digits[None, :, :, lvl, None], axis=0)[0]
        prod = _tree_hom_prod(sel, mod)
        acc = _lib_mont_mul(acc, prod, mod)
    return acc


def _tree_hom_prod(c: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    """⊕-reduce axis 0 (log-depth; same schedule as protocols')."""
    while c.shape[0] > 1:
        half = c.shape[0] // 2
        merged = _lib_mont_mul(c[:half], c[half:2 * half], mod)
        if c.shape[0] % 2:
            merged = jnp.concatenate([merged, c[2 * half:]], axis=0)
        c = merged
    return c[0]


def _matvec_fn(engine: CryptoEngine, mod: Modulus, window: int):
    """Build (or fetch) the jitted shard_map body for the sharded
    windowed matvec."""
    key = ("matvec", engine, mod.value, window)
    fn = _BODY_CACHE.get(key)
    if fn is not None:
        return fn
    inner = engine.single_device()
    mesh, axis = engine.mesh, engine.mesh_axis
    size = mesh.shape[axis]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None, None)),
        out_specs=P(axis, None, None),
        check_vma=False)
    def body(cts_loc, dig_loc):
        part = _windowed_partial(inner, cts_loc, dig_loc, mod, window)
        return modmul_reduce(part, mod, axis, size)[None]

    fn = jax.jit(body)
    _BODY_CACHE[key] = fn
    return fn


def sharded_he_matvec(engine: CryptoEngine, cts: jnp.ndarray, digits,
                      mod: Modulus, window: int) -> jnp.ndarray:
    """Windowed HE matvec with the ciphertext-row axis sharded over the
    mesh: each device folds its row shard into an (m, L) partial, then
    the partials ⊕-combine across devices with the `modmul_reduce`
    butterfly (Paillier ⊕ is modular multiplication — psum can't express
    it).  cts: (n, L); digits: (n, m, levels) MSB-first window digits;
    returns (m, L), bit-exact vs the single-device engine."""
    size = engine.mesh.shape[engine.mesh_axis]
    cts = _pad_rows(jnp.asarray(cts, _U32), size)
    digits = _pad_rows(jnp.asarray(digits, _U32), size)
    return _matvec_fn(engine, mod, window)(cts, digits)[0]
