"""jax version compat for shard_map.

Newer jax exports `jax.shard_map` (replication check kwarg `check_vma`);
the pinned toolchain still ships it as `jax.experimental.shard_map`
(kwarg `check_rep`).  This wrapper presents the new-style surface either
way so the mesh-lowering code has one spelling.
"""
from __future__ import annotations

import functools

try:                                          # jax >= 0.6 style
    from jax import shard_map as _shard_map
    _REP_KW = "check_vma"
except ImportError:                           # pinned toolchain
    from jax.experimental.shard_map import shard_map as _shard_map
    _REP_KW = "check_rep"


def shard_map(f=None, **kwargs):
    if "check_vma" in kwargs and _REP_KW == "check_rep":
        kwargs["check_rep"] = kwargs.pop("check_vma")
    if f is None:
        return functools.partial(shard_map, **kwargs)
    return _shard_map(f, **kwargs)
