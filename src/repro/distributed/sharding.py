"""Sharding rules: param/state/batch PartitionSpecs for the production mesh.

Axis roles (DESIGN.md §5):
  data  — FSDP (params+opt sharded), batch, sequence (SP fallback)
  model — TP (heads / ffn hidden / vocab), EP (experts), KV-cache seq
  pod   — DP across pods (params replicated, gradients all-reduced)

Rules are name-based with a divisibility guard: a dim is only sharded if
it divides by the axis size, otherwise that dim falls back to replication
(this is what makes whisper-base's 51865 vocab lower cleanly on the same
rules that shard kimi's 163840).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# name -> spec template for the *trailing* dims (leading stack dims -> None)
_RULES_2D: dict[str, tuple] = {
    # (in, out)-style projections: FSDP on in-dim, TP on out-dim
    "wq": ("data", "model"), "wk": ("data", "model"),
    "wv": ("data", "model"), "wg": ("data", "model"),
    "wr": ("data", "model"), "up": ("data", "model"),
    "gate": ("data", "model"), "ck": ("data", "model"),
    "cr": ("data", "model"), "w_in": ("data", "model"),
    "wA": ("data", "model"),
    # output projections: TP on in-dim, FSDP on out-dim
    "wo": ("model", "data"), "down": ("model", "data"),
    "cv": ("model", "data"), "w_out": ("model", "data"),
    "wB": ("model", "data"),
    # embeddings / heads
    "embed": ("model", "data"), "head": ("data", "model"),
    "pos_dec": (None, "data"),
    "router": ("data", None),
    "conv_w": (None, "model"),
}
_RULES_3D: dict[str, tuple] = {
    "w_gate": ("model", "data", None),      # (E, D, F): EP × FSDP
    "w_up": ("model", "data", None),
    "w_down": ("model", None, "data"),
}


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _guard(spec: tuple, shape: tuple, mesh: Mesh) -> P:
    """Drop axes that don't divide the corresponding dim."""
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if ax is not None and dim % _axis_size(mesh, ax) == 0
                   else None)
    return P(*out)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return entry.name
    return ""


def param_spec_for(name: str, shape: tuple, mesh: Mesh) -> P:
    if len(shape) <= 1:
        return P()
    # strip leading stack dims (layer-scan) so rules match trailing dims
    if name in _RULES_3D and len(shape) >= 3:
        tmpl = _RULES_3D[name]
        lead = (None,) * (len(shape) - 3)
        return _guard(lead + tuple(tmpl), shape, mesh)
    if name in _RULES_2D:
        tmpl = _RULES_2D[name]
        lead = (None,) * (len(shape) - 2)
        return _guard(lead + tuple(tmpl), shape, mesh)
    # default: try to FSDP the largest trailing dim
    spec = [None] * len(shape)
    order = np.argsort(shape[-2:])[::-1]
    axes = ["data", "model"]
    for i, di in enumerate(order):
        dim_idx = len(shape) - 2 + di
        if shape[dim_idx] % _axis_size(mesh, axes[i]) == 0:
            spec[dim_idx] = axes[i]
    return P(*spec)


def param_specs(shape_tree: Any, mesh: Mesh) -> Any:
    def per_leaf(path, leaf):
        return NamedSharding(mesh, param_spec_for(
            _leaf_name(path), tuple(leaf.shape), mesh))
    return jax.tree_util.tree_map_with_path(per_leaf, shape_tree)


# ---------------------------------------------------------------------------
# Decode-state / cache specs
# ---------------------------------------------------------------------------

def cache_spec(shape: tuple, mesh: Mesh, batch_dim: int = 1,
               seq_dim: int = 2, kv_dim: int | None = 3) -> P:
    """(…, B, S, K, hd)-style caches: batch→data, kv-heads→model if they
    divide, else the sequence dim takes the leftover axes (the SP/KV-seq
    fallback that keeps 61-layer × 32k × 128-batch caches on-chip)."""
    spec: list = [None] * len(shape)
    data_ok = shape[batch_dim] % _axis_size(mesh, "data") == 0
    if data_ok:
        spec[batch_dim] = "data"
    kv_ok = (kv_dim is not None and kv_dim < len(shape)
             and shape[kv_dim] % _axis_size(mesh, "model") == 0)
    if kv_ok:
        spec[kv_dim] = "model"
    else:
        leftover = ("model",) if data_ok else ("data", "model")
        if seq_dim is not None and \
                shape[seq_dim] % _axis_size(mesh, leftover) == 0:
            spec[seq_dim] = leftover if len(leftover) > 1 else leftover[0]
    return P(*spec)


def state_specs(state_shapes: Any, mesh: Mesh) -> Any:
    """Specs for decode-state pytrees (StackedCache / RWKVState /
    MambaState / WhisperCache) by rank heuristics."""
    def per_leaf(path, leaf):
        shape = tuple(leaf.shape)
        name = _leaf_name(path)
        if len(shape) == 5:              # (L, B, S|H, K|hd, hd) caches/state
            if name in ("k", "v", "attn_k", "attn_v", "k_scale", "v_scale"):
                return NamedSharding(mesh, cache_spec(shape, mesh))
            # rwkv wkv state (L, B, H, hd, hd) / mamba h (L, B, hm, P, N)
            spec = [None] * 5
            if shape[1] % _axis_size(mesh, "data") == 0:
                spec[1] = "data"
            if shape[2] % _axis_size(mesh, "model") == 0:
                spec[2] = "model"
            return NamedSharding(mesh, P(*spec))
        if len(shape) == 4:              # (L, B, x, C) conv tails etc.
            spec = [None] * 4
            if shape[1] % _axis_size(mesh, "data") == 0:
                spec[1] = "data"
            if shape[-1] % _axis_size(mesh, "model") == 0:
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        if len(shape) == 3:              # (L, B, D) shift states
            spec = [None] * 3
            if shape[1] % _axis_size(mesh, "data") == 0:
                spec[1] = "data"
            if shape[-1] % _axis_size(mesh, "model") == 0:
                spec[-1] = "model"
            return NamedSharding(mesh, P(*spec))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map_with_path(per_leaf, state_shapes)


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def batch_specs(batch_shapes: Any, mesh: Mesh) -> Any:
    ba = batch_axes(mesh)
    basz = _axis_size(mesh, tuple(ba))

    def per_leaf(leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if shape and shape[0] % basz == 0:
            spec[0] = ba if len(ba) > 1 else ba[0]
        elif shape and shape[0] % _axis_size(mesh, "data") == 0:
            spec[0] = "data"
        return NamedSharding(mesh, P(*spec))
    return jax.tree.map(per_leaf, batch_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
