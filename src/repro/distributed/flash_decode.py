"""Flash-decode: single-token attention against a SEQUENCE-SHARDED KV
cache without gathering the cache (§Perf; the principled fix for archs
whose KV heads cannot shard the TP axis, e.g. gemma3's 8 query heads).

Each shard computes partial attention over its local KV chunk, then the
shards combine with the numerically-stable log-sum-exp merge:

    m  = pmax(m_loc)                 (per (batch, head))
    num = psum(exp(m_loc − m) · acc_loc)
    den = psum(exp(m_loc − m) · den_loc)
    out = num / den

Wire cost per layer: 2·B·H·hd·f32 (+ B·H) — hundreds of KB, vs. the
multi-GB cache gather XLA otherwise inserts.  Exact (same softmax).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


def local_partial_attention(q, k_loc, v_loc, valid_loc, softcap=None):
    """q: (B, G, Hg, hd) f32; k/v_loc: (B, S_loc, G, hd); valid_loc:
    (S_loc,) bool mask for positions < length within this shard.
    Returns (acc (B,G,Hg,hd), m (B,G,Hg), den (B,G,Hg))."""
    hd = q.shape[-1]
    scale = 1.0 / np.sqrt(hd)
    s = jnp.einsum("bghd,bkgd->bghk", q, k_loc.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(valid_loc[None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid_loc[None, None, None, :], p, 0.0)
    acc = jnp.einsum("bghk,bkgd->bghd", p, v_loc.astype(jnp.float32))
    den = p.sum(axis=-1)
    return acc, m, den


def merge_partials(acc, m, den, axis_names):
    """Cross-shard log-sum-exp merge over `axis_names` (psum/pmax)."""
    m_glob = jax.lax.pmax(m, axis_names)
    scale = jnp.exp(m - m_glob)
    num = jax.lax.psum(acc * scale[..., None], axis_names)
    d = jax.lax.psum(den * scale, axis_names)
    return num / jnp.maximum(d[..., None], 1e-30)


def make_flash_decode(mesh, seq_axis: str | tuple, B: int, S: int,
                      G: int, Hg: int, hd: int, softcap=None):
    """Builds a shard_map'd decode-attention: cache stays sharded on its
    sequence dim over `seq_axis`; only (B,G,Hg,hd)-sized partials move."""
    from repro.distributed.shardmap_compat import shard_map
    from jax.sharding import PartitionSpec as P

    axes = seq_axis if isinstance(seq_axis, tuple) else (seq_axis,)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    S_loc = S // n_shards
    spec_cache = P(None, axes if len(axes) > 1 else axes[0], None, None)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P(), spec_cache, spec_cache, P()),
        out_specs=P(),
        check_vma=False)
    def flash(q, k, v, length):
        idx = jax.lax.axis_index(axes[0])
        if len(axes) > 1:
            for a in axes[1:]:
                idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        base = idx * S_loc
        kpos = base + jnp.arange(S_loc)
        valid = kpos < length
        acc, m, den = local_partial_attention(q, k, v, valid, softcap)
        return merge_partials(acc, m, den, axes)

    return flash


# ---------------------------------------------------------------------------
# Standalone production-mesh lowering proof (gemma3-shaped decode layer):
#   XLA_FLAGS="--xla_force_host_platform_device_count=512" \
#   PYTHONPATH=src python -m repro.distributed.flash_decode
# ---------------------------------------------------------------------------

def _main() -> None:   # pragma: no cover (driver)
    import json
    import os
    from repro.launch import mesh as mesh_lib

    mesh = mesh_lib.make_production_mesh()
    # gemma3-4b decode_32k shapes: B=128, S=32768, G=4 kv, Hg=2, hd=256
    B, S, G, Hg, hd = 128, 32768, 4, 2, 256
    flash = make_flash_decode(mesh, ("data", "model"), B, S, G, Hg, hd,
                              softcap=50.0)
    specs = (jax.ShapeDtypeStruct((B, G, Hg, hd), jnp.float32),
             jax.ShapeDtypeStruct((B, S, G, hd), jnp.bfloat16),
             jax.ShapeDtypeStruct((B, S, G, hd), jnp.bfloat16),
             jax.ShapeDtypeStruct((), jnp.int32))
    compiled = jax.jit(flash).lower(*specs).compile()
    from repro.launch.dryrun import parse_collectives, peak_bytes
    census = parse_collectives(compiled.as_text())
    out = {"kind": "flash_decode_gemma3_layer", "mesh": "16x16",
           "peak_bytes_per_dev": peak_bytes(
               compiled.memory_analysis()),
           "collectives": census, "ok": True}
    print(json.dumps(out, indent=1))
    os.makedirs("results", exist_ok=True)
    with open("results/flash_decode_gemma3.json", "w") as f:
        json.dump(out, f, indent=1)


if __name__ == "__main__":   # pragma: no cover
    import os as _os
    assert "512" in _os.environ.get("XLA_FLAGS", ""), \
        "run with XLA_FLAGS=--xla_force_host_platform_device_count=512"
    _main()
