"""Distribution layer: sharding rules (FSDP×TP×EP×SP), secure collectives,
gradient compression, elastic resharding, and the mesh-sharded HE engine
(`he_sharding.ShardedCryptoEngine` — ciphertext-batch data parallelism
for the Paillier hot path, bit-exact vs the single-device engine)."""
