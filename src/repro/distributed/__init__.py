"""Distribution layer: sharding rules (FSDP×TP×EP×SP), secure collectives,
gradient compression, elastic resharding."""
