"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.crypto import bigint, ring
from repro.crypto.bigint import Modulus
from repro.crypto.ring import R64


def montmul_ref(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus) -> jnp.ndarray:
    """Reference Montgomery product: the library's vectorized limb code
    (itself validated against python ints in tests/test_crypto_bigint)."""
    return bigint.mont_mul(a, b, mod)


def ring_matmul_ref(a: R64, b: R64) -> R64:
    """(M, K) @ (K, N) over Z_2^64 with scalar ring ops (memory-light
    scan over K)."""
    M, K = a.lo.shape
    N = b.lo.shape[1]
    acc0 = ring.zeros((M, N))

    def body(k, acc):
        ak = R64(jax.lax.dynamic_slice_in_dim(a.hi, k, 1, 1),
                 jax.lax.dynamic_slice_in_dim(a.lo, k, 1, 1))     # (M, 1)
        bk = R64(jax.lax.dynamic_slice_in_dim(b.hi, k, 1, 0),
                 jax.lax.dynamic_slice_in_dim(b.lo, k, 1, 0))     # (1, N)
        return ring.add(acc, ring.mul(ak, bk))

    return jax.lax.fori_loop(0, K, body, acc0)
