"""Pallas TPU kernel: Z_{2^64} matrix multiplication via limb-decomposed
integer MXU contractions.

Secret-share linear algebra (SS-LR baselines, Beaver-based dot products,
X^T·⟨d⟩ in mod-2^64 semantics) is matmul over the ring Z_2^64.  TPUs have
no 64-bit integer units, but the MXU eats low-precision integer matmuls.
We split each 64-bit operand into eight 8-bit limbs and evaluate the 36
partial-product contractions whose weight 2^{8(i+j)} survives mod 2^64:

    C = Σ_{i+j ≤ 7}  (A_i @ B_j) · 2^{8(i+j)}   (mod 2^64)

Each A_i @ B_j is an integer matmul with operands < 2^8 and K ≤ 2^15, so
int32 accumulation is exact.  Recombination lifts each partial into a
(hi, lo) uint32 pair and shift-adds — pure VPU work.

  grid   : (M/TM, N/TN)
  blocks : A hi/lo (TM, K), B hi/lo (K, TN), out hi/lo (TM, TN) in VMEM
  VMEM   : (2·TM·K + 2·K·TN + 2·TM·TN) × 4 B — e.g. TM=TN=128, K=2048
           → 4.3 MB (ops.py splits larger K and carries between chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_U32 = jnp.uint32
MAX_K_EXACT = 1 << 15        # 255*255*K < 2^31 → K ≤ 32768

DEFAULT_TM = 128
DEFAULT_TN = 128


def _limbs8(hi: jnp.ndarray, lo: jnp.ndarray) -> list[jnp.ndarray]:
    """(…) uint32 pair -> eight (…) int32 planes of 8-bit limbs (LSB first).
    int32 planes (values 0..255) hit the MXU integer path on TPU; interpret
    mode evaluates them as plain integer dots."""
    out = []
    for w, src in ((0, lo), (1, hi)):
        for s in range(4):
            out.append(((src >> (8 * s)) & _U32(0xFF)).astype(jnp.int32))
    return out


def _shift_add_u64(acc_hi, acc_lo, p: jnp.ndarray, shift_bits: int):
    """acc (uint32 pair) += p · 2^shift_bits (p: int32 ≥ 0, < 2^31)."""
    p = p.astype(_U32)
    if shift_bits == 0:
        add_hi, add_lo = jnp.zeros_like(p), p
    elif shift_bits < 32:
        add_lo = p << shift_bits
        add_hi = p >> (32 - shift_bits)
    elif shift_bits == 32:
        add_hi, add_lo = p, jnp.zeros_like(p)
    else:
        add_hi, add_lo = p << (shift_bits - 32), jnp.zeros_like(p)
    new_lo = acc_lo + add_lo
    carry = (new_lo < acc_lo).astype(_U32)
    return acc_hi + add_hi + carry, new_lo


def _kernel(a_hi_ref, a_lo_ref, b_hi_ref, b_lo_ref, o_hi_ref, o_lo_ref):
    a_limbs = _limbs8(a_hi_ref[...], a_lo_ref[...])   # 8 × (TM, K)
    b_limbs = _limbs8(b_hi_ref[...], b_lo_ref[...])   # 8 × (K, TN)
    shape = (a_limbs[0].shape[0], b_limbs[0].shape[1])
    acc_hi = jnp.zeros(shape, _U32)
    acc_lo = jnp.zeros(shape, _U32)
    for i in range(8):
        for j in range(8 - i):                        # weight < 2^64 only
            p = jax.lax.dot_general(
                a_limbs[i], b_limbs[j],
                dimension_numbers=(((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)     # MXU int contraction
            acc_hi, acc_lo = _shift_add_u64(acc_hi, acc_lo, p, 8 * (i + j))
    o_hi_ref[...] = acc_hi
    o_lo_ref[...] = acc_lo


@functools.partial(jax.jit, static_argnames=("tm", "tn", "interpret"))
def ring_matmul_tiled(a_hi, a_lo, b_hi, b_lo, *, tm: int = DEFAULT_TM,
                      tn: int = DEFAULT_TN, interpret: bool = True):
    """(M, K) × (K, N) over Z_2^64; M % tm == N % tn == 0, K ≤ 2^15
    (ops.py handles padding and K-chunking)."""
    M, K = a_hi.shape
    N = b_hi.shape[1]
    assert M % tm == 0 and N % tn == 0 and K <= MAX_K_EXACT
    grid = (M // tm, N // tn)
    out_shape = [jax.ShapeDtypeStruct((M, N), jnp.uint32)] * 2
    a_spec = pl.BlockSpec((tm, K), lambda i, j: (i, 0))
    b_spec = pl.BlockSpec((K, tn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((tm, tn), lambda i, j: (i, j))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[o_spec, o_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(a_hi, a_lo, b_hi, b_lo)
