"""Fused Pallas TPU kernels for the Paillier hot path: the full
constant-time Montgomery ladder and the windowed HE matvec, each inside
ONE `pallas_call`.

`ops.mont_exp_bits` runs the ladder as 2×nbits separate `montmul_tiled`
launches — every square and every multiply round-trips the accumulator
through HBM.  The two kernels here keep the working set resident in
VMEM for the whole ladder:

* `mont_exp_fused` — grid (batch/TILE_B,); blocks base (TILE_B, L),
  bits (TILE_B, nbits), N and R mod N (1, L).  The square/select/multiply
  loop is a `fori_loop` over nbits with two `_montmul_block` calls per
  step; the select is a lane-wise `where`, so the ladder stays
  constant-time (appropriate for secret exponents).  VMEM per program:
  ~4 blocks × TILE_B × L × 4 B ≈ 0.4 MB at TILE_B=128, L=176 (2048-bit)
  plus TILE_B × nbits bits.

* `he_matvec_fused` — Protocol 3's plaintext-matrix × ciphertext-vector
  product, fixed-window form.  Grid (m/TILE_M,); blocks cts (n, L),
  digits (levels, n, TILE_M) (MSB-first window digits, precomputed once
  per batch by `protocols.EncodedFeatures`).  The kernel builds the
  2^window power table in VMEM, then per digit level folds the selected
  powers into a running ⊕-product and squares the accumulator `window`
  times.  Sequential fold and the library's tree fold compute the same
  group element, and canonical Montgomery residues are unique, so the
  output is bit-exact vs `protocols._he_matvec_windowed`.  VMEM per
  program: table 2^w × n × L × 4 B — the `ops.he_matvec_fused` wrapper
  chunks n to keep this bounded (chunk outputs combine homomorphically,
  again bit-exact).

Both kernels reuse `montmul._montmul_block` (traced inline, so each
kernel's IR is still self-contained when it ships to Mosaic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto import rns as rns_lib
from repro.kernels.montmul import _montmul_block

_U32 = jnp.uint32

DEFAULT_TILE_B = 128
DEFAULT_TILE_M = 128
DEFAULT_CHUNK_N = 512


# ---------------------------------------------------------------------------
# Fused constant-time ladder
# ---------------------------------------------------------------------------

def _exp_kernel(n0inv: int, L: int, nbits: int,
                base_ref, bits_ref, n_ref, r1_ref, o_ref):
    base = base_ref[...]                        # (TB, L)
    bits = bits_ref[...]                        # (TB, nbits) MSB-first
    n = n_ref[...]                              # (1, L)
    acc0 = jnp.broadcast_to(r1_ref[...], base.shape)   # mont(1)

    def step(i, acc):
        acc = _montmul_block(acc, acc, n, n0inv, L)
        mul = _montmul_block(acc, base, n, n0inv, L)
        bit = jax.lax.dynamic_slice_in_dim(bits, i, 1, axis=1)   # (TB, 1)
        return jnp.where(bit == 1, mul, acc)

    o_ref[...] = jax.lax.fori_loop(0, nbits, step, acc0)


@functools.partial(jax.jit, static_argnames=("n0inv", "L", "tile_b",
                                             "interpret"))
def mont_exp_tiled(base: jnp.ndarray, bits: jnp.ndarray, n: jnp.ndarray,
                   r1: jnp.ndarray, *, n0inv: int, L: int,
                   tile_b: int = DEFAULT_TILE_B,
                   interpret: bool = True) -> jnp.ndarray:
    """base: (batch, L) Montgomery-domain canonical; bits: (batch, nbits)
    MSB-first.  Returns base^e in the Montgomery domain, canonical.
    batch must be a multiple of tile_b (ops.py pads)."""
    batch, nbits = bits.shape
    assert base.shape == (batch, L)
    assert batch % tile_b == 0, "pad batch to a tile multiple in ops.py"
    grid = (batch // tile_b,)
    return pl.pallas_call(
        functools.partial(_exp_kernel, n0inv, L, nbits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, nbits), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, L), jnp.uint32),
        interpret=interpret,
    )(base, bits, n.reshape(1, L), r1.reshape(1, L))


# ---------------------------------------------------------------------------
# Fused windowed HE matvec
# ---------------------------------------------------------------------------

def _matvec_kernel(n0inv: int, L: int, window: int, levels: int,
                   nrows: int, cts_ref, dig_ref, n_ref, r1_ref, o_ref):
    cts = cts_ref[...]                          # (nrows, L)
    digs = dig_ref[...]                         # (levels, nrows, TM)
    n = n_ref[...]                              # (1, L)
    one = r1_ref[...]                           # (1, L)
    TM = o_ref.shape[0]
    npow = 1 << window

    # power table c_i^j for j < 2^window: (npow, nrows, L) in VMEM
    table = jnp.zeros((npow, nrows, L), _U32)
    table = table.at[0].set(jnp.broadcast_to(one, (nrows, L)))
    table = table.at[1].set(cts)

    def build(j, tab):
        prev = jax.lax.dynamic_index_in_dim(tab, j - 1, axis=0,
                                            keepdims=False)
        nxt = _montmul_block(prev, cts, n, n0inv, L)
        return jax.lax.dynamic_update_index_in_dim(tab, nxt, j, axis=0)

    table = jax.lax.fori_loop(2, npow, build, table)

    acc = jnp.broadcast_to(one, (TM, L))
    for lvl in range(levels):                   # static: levels ≈ 6
        for _ in range(window):
            acc = _montmul_block(acc, acc, n, n0inv, L)
        dig_lvl = digs[lvl]                     # (nrows, TM)

        def row(i, p):
            di = jax.lax.dynamic_index_in_dim(dig_lvl, i, axis=0,
                                              keepdims=False)      # (TM,)
            row_tab = jax.lax.dynamic_index_in_dim(table, i, axis=1,
                                                   keepdims=False)  # (npow, L)
            # one-hot select (no gather: TPU-friendly lane-wise wheres)
            sel = jnp.broadcast_to(one, (TM, L))
            for j in range(1, npow):
                sel = jnp.where((di == j)[:, None], row_tab[j][None], sel)
            return _montmul_block(p, sel, n, n0inv, L)

        prod = jax.lax.fori_loop(0, nrows, row,
                                 jnp.broadcast_to(one, (TM, L)))
        acc = _montmul_block(acc, prod, n, n0inv, L)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("n0inv", "L", "window",
                                             "tile_m", "interpret"))
def he_matvec_tiled(cts: jnp.ndarray, digits: jnp.ndarray, n: jnp.ndarray,
                    r1: jnp.ndarray, *, n0inv: int, L: int, window: int,
                    tile_m: int = DEFAULT_TILE_M,
                    interpret: bool = True) -> jnp.ndarray:
    """cts: (nrows, L) Montgomery ciphertexts; digits: (levels, nrows, m)
    MSB-first window digits.  Returns (m, L) ciphertexts of
    Σ_i digit-value_i · m_i.  m must be a multiple of tile_m (ops.py
    pads with zero digits — the padded columns fold to mont(1) and are
    dropped)."""
    levels, nrows, m = digits.shape
    assert cts.shape == (nrows, L)
    assert m % tile_m == 0, "pad m to a tile multiple in ops.py"
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_matvec_kernel, n0inv, L, window, levels, nrows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nrows, L), lambda i: (0, 0)),
            pl.BlockSpec((levels, nrows, tile_m), lambda i: (0, 0, i)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, L), jnp.uint32),
        interpret=interpret,
    )(cts, digits, n.reshape(1, L), r1.reshape(1, L))


# ---------------------------------------------------------------------------
# RNS channel-domain fused kernels (the compiled pipeline — crypto/rns.py)
# ---------------------------------------------------------------------------
#
# Same fusion story as above, but every Montgomery product is ONE RNS
# round (`rns.montmul_channels` traced inline): channel-pointwise VPU ops
# plus two exact f32 base-extension matmuls that map onto the MXU.  All
# three kernels work on channel states in the ·B domain; the limbs ↔
# channels conversions and the final exact reconstruction (`rns.from_rns`)
# stay outside in ops.py, amortized over the whole ladder / matvec / table
# walk.  Per-program VMEM at CH=166 (1024-bit n²): ladder ~4 blocks ×
# TILE_B × CH × 4 B ≈ 0.4 MB; matvec table 2^w × n_chunk × CH × 4 B —
# ops.py chunks n exactly as it does for the CIOS kernel.

def _rns_mm(mods, tb, ta, vecs, kA, kB, ainv_r):
    return functools.partial(rns_lib.montmul_channels, mods=mods, t_b=tb,
                             t_a=ta, vecs=vecs, kA=kA, kB=kB,
                             ainv_r=ainv_r)


def _rns_exp_kernel(kA: int, kB: int, ainv_r: int, nbits: int,
                    u_ref, bits_ref, mods_ref, tb_ref, ta_ref, vecs_ref,
                    one_ref, exit_ref, o_ref):
    u = u_ref[...]                               # (TB, CH) scaled base
    bits = bits_ref[...]                         # (TB, nbits) MSB-first
    mm = _rns_mm(mods_ref[...], tb_ref[...], ta_ref[...], vecs_ref[...],
                 kA, kB, ainv_r)
    acc0 = jnp.broadcast_to(one_ref[...], u.shape)

    def step(i, acc):
        acc = mm(acc, acc)
        mul = mm(acc, u)
        bit = jax.lax.dynamic_slice_in_dim(bits, i, 1, axis=1)   # (TB, 1)
        return jnp.where(bit == 1, mul, acc)

    acc = jax.lax.fori_loop(0, nbits, step, acc0)
    o_ref[...] = mm(acc, exit_ref[...])          # v^e·B ↦ v^e·R


@functools.partial(jax.jit, static_argnames=("kA", "kB", "ainv_r",
                                             "tile_b", "interpret"))
def rns_mont_exp_tiled(u: jnp.ndarray, bits: jnp.ndarray,
                       mods: jnp.ndarray, t_b: jnp.ndarray,
                       t_a: jnp.ndarray, vecs: jnp.ndarray,
                       one: jnp.ndarray, exitc: jnp.ndarray, *,
                       kA: int, kB: int, ainv_r: int,
                       tile_b: int = DEFAULT_TILE_B,
                       interpret: bool = True) -> jnp.ndarray:
    """Fused constant-time ladder on channel states.  u: (batch, CH) the
    base via `rns.to_rns_scaled`; bits: (batch, nbits) MSB-first.
    Returns the channel state of base^e·R (< (kB+2)·N) — finish with
    `rns.from_rns` outside."""
    batch, nbits = bits.shape
    CH = u.shape[1]
    assert batch % tile_b == 0, "pad batch to a tile multiple in ops.py"
    grid = (batch // tile_b,)
    return pl.pallas_call(
        functools.partial(_rns_exp_kernel, kA, kB, ainv_r, nbits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, nbits), lambda i: (i, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec(t_b.shape, lambda i: (0, 0)),
            pl.BlockSpec(t_a.shape, lambda i: (0, 0)),
            pl.BlockSpec((6, CH), lambda i: (0, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, CH), jnp.uint32),
        interpret=interpret,
    )(u, bits, mods.reshape(1, CH), t_b, t_a, vecs,
      one.reshape(1, CH), exitc.reshape(1, CH))


def _rns_matvec_kernel(kA: int, kB: int, ainv_r: int, window: int,
                       levels: int, nrows: int,
                       u_ref, dig_ref, mods_ref, tb_ref, ta_ref, vecs_ref,
                       one_ref, o_ref):
    u = u_ref[...]                               # (nrows, CH) scaled cts
    digs = dig_ref[...]                          # (levels, nrows, TM)
    one = one_ref[...]                           # (1, CH)
    mm = _rns_mm(mods_ref[...], tb_ref[...], ta_ref[...], vecs_ref[...],
                 kA, kB, ainv_r)
    TM = o_ref.shape[0]
    CH = u.shape[1]
    npow = 1 << window

    # power table c_i^j·B for j < 2^window: (npow, nrows, CH) in VMEM
    table = jnp.zeros((npow, nrows, CH), _U32)
    table = table.at[0].set(jnp.broadcast_to(one, (nrows, CH)))
    table = table.at[1].set(u)

    def build(j, tab):
        prev = jax.lax.dynamic_index_in_dim(tab, j - 1, axis=0,
                                            keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(tab, mm(prev, u), j,
                                                   axis=0)

    table = jax.lax.fori_loop(2, npow, build, table)

    acc = jnp.broadcast_to(one, (TM, CH))
    for lvl in range(levels):                    # static: levels ≈ 6
        for _ in range(window):
            acc = mm(acc, acc)
        dig_lvl = digs[lvl]                      # (nrows, TM)

        def row(i, p):
            di = jax.lax.dynamic_index_in_dim(dig_lvl, i, axis=0,
                                              keepdims=False)       # (TM,)
            row_tab = jax.lax.dynamic_index_in_dim(table, i, axis=1,
                                                   keepdims=False)  # (npow, CH)
            sel = jnp.broadcast_to(one, (TM, CH))
            for j in range(1, npow):
                sel = jnp.where((di == j)[:, None], row_tab[j][None], sel)
            return mm(p, sel)

        prod = jax.lax.fori_loop(0, nrows, row,
                                 jnp.broadcast_to(one, (TM, CH)))
        acc = mm(acc, prod)
    o_ref[...] = acc                             # ·B domain — exit outside


@functools.partial(jax.jit, static_argnames=("kA", "kB", "ainv_r",
                                             "window", "tile_m",
                                             "interpret"))
def rns_he_matvec_tiled(u: jnp.ndarray, digits: jnp.ndarray,
                        mods: jnp.ndarray, t_b: jnp.ndarray,
                        t_a: jnp.ndarray, vecs: jnp.ndarray,
                        one: jnp.ndarray, *, kA: int, kB: int,
                        ainv_r: int, window: int,
                        tile_m: int = DEFAULT_TILE_M,
                        interpret: bool = True) -> jnp.ndarray:
    """Fused windowed HE matvec on channel states.  u: (nrows, CH) the
    ciphertexts via `rns.to_rns_scaled`; digits: (levels, nrows, m)
    MSB-first window digits.  Returns (m, CH) ·B-domain channel states of
    the column products — chunk-⊕, exit, and `rns.from_rns` happen in
    ops.py."""
    levels, nrows, m = digits.shape
    CH = u.shape[1]
    assert m % tile_m == 0, "pad m to a tile multiple in ops.py"
    grid = (m // tile_m,)
    return pl.pallas_call(
        functools.partial(_rns_matvec_kernel, kA, kB, ainv_r, window,
                          levels, nrows),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nrows, CH), lambda i: (0, 0)),
            pl.BlockSpec((levels, nrows, tile_m), lambda i: (0, 0, i)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec(t_b.shape, lambda i: (0, 0)),
            pl.BlockSpec(t_a.shape, lambda i: (0, 0)),
            pl.BlockSpec((6, CH), lambda i: (0, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, CH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, CH), jnp.uint32),
        interpret=interpret,
    )(u, digits, mods.reshape(1, CH), t_b, t_a, vecs, one.reshape(1, CH))


def _rns_fixb_kernel(kA: int, kB: int, ainv_r: int, window: int,
                     levels: int,
                     tab_ref, dig_ref, mods_ref, tb_ref, ta_ref, vecs_ref,
                     one_ref, exit_ref, o_ref):
    tab = tab_ref[...]                           # (levels, npow, CH)
    digs = dig_ref[...]                          # (TB, levels) LSB-first
    one = one_ref[...]                           # (1, CH)
    mm = _rns_mm(mods_ref[...], tb_ref[...], ta_ref[...], vecs_ref[...],
                 kA, kB, ainv_r)
    TB = digs.shape[0]
    CH = tab.shape[-1]
    npow = 1 << window
    acc0 = jnp.broadcast_to(one, (TB, CH))

    def step(lvl, acc):
        t_lvl = jax.lax.dynamic_index_in_dim(tab, lvl, axis=0,
                                             keepdims=False)  # (npow, CH)
        d = jax.lax.dynamic_slice_in_dim(digs, lvl, 1, axis=1)  # (TB, 1)
        # digit 0 selects table[lvl][0] = one — mm(acc, one) is identity
        sel = jnp.broadcast_to(one, (TB, CH))
        for j in range(1, npow):
            sel = jnp.where(d == j, t_lvl[j][None], sel)
        return mm(acc, sel)

    acc = jax.lax.fori_loop(0, levels, step, acc0)
    o_ref[...] = mm(acc, exit_ref[...])          # h^e·B ↦ h^e·R


@functools.partial(jax.jit, static_argnames=("kA", "kB", "ainv_r",
                                             "window", "tile_b",
                                             "interpret"))
def rns_fixed_base_tiled(table: jnp.ndarray, digits: jnp.ndarray,
                         mods: jnp.ndarray, t_b: jnp.ndarray,
                         t_a: jnp.ndarray, vecs: jnp.ndarray,
                         one: jnp.ndarray, exitc: jnp.ndarray, *,
                         kA: int, kB: int, ainv_r: int, window: int,
                         tile_b: int = DEFAULT_TILE_B,
                         interpret: bool = True) -> jnp.ndarray:
    """Fixed-base windowed exponentiation from a prepared ·B-domain
    table (levels, 2^window, CH); digits: (batch, levels) LSB-first
    base-2^window digits of the exponent.  Returns the channel state of
    h^e·R — finish with `rns.from_rns` outside.  The whole walk is one
    table-lookup ⊕ per level: ~levels RNS rounds instead of 2·nbits
    ladder rounds."""
    batch, levels = digits.shape
    CH = table.shape[-1]
    npow = 1 << window
    assert table.shape == (levels, npow, CH)
    assert batch % tile_b == 0, "pad batch to a tile multiple in ops.py"
    grid = (batch // tile_b,)
    return pl.pallas_call(
        functools.partial(_rns_fixb_kernel, kA, kB, ainv_r, window,
                          levels),
        grid=grid,
        in_specs=[
            pl.BlockSpec((levels, npow, CH), lambda i: (0, 0, 0)),
            pl.BlockSpec((tile_b, levels), lambda i: (i, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec(t_b.shape, lambda i: (0, 0)),
            pl.BlockSpec(t_a.shape, lambda i: (0, 0)),
            pl.BlockSpec((6, CH), lambda i: (0, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, CH), jnp.uint32),
        interpret=interpret,
    )(table, digits, mods.reshape(1, CH), t_b, t_a, vecs,
      one.reshape(1, CH), exitc.reshape(1, CH))
