"""Pallas TPU kernel: batched Montgomery modular multiplication.

The compute hot spot of EFMVFL is Paillier arithmetic — Protocol 3's
plaintext-matrix × ciphertext-vector product is millions of Montgomery
products over 2048-bit residues.  This kernel evaluates a *batch* of
Montgomery products entirely in VMEM:

  grid     : (batch / TILE_B,)
  blocks   : A, B, out — (TILE_B, L) uint32 limb planes in VMEM
             N          — (1, L) broadcast to every program
  compute  : the radix-2^12 CIOS loop (see crypto/bigint.py) — limb
             products ≤ 2^24 accumulate in native int32/uint32 vector
             lanes; one lazy-carry pass per round keeps limbs < 2^16.

TPU adaptation notes (DESIGN.md §3): word-serial bignum code (gmp-style)
has no TPU analogue — no 64-bit multiplier, no carry flag.  Radix-2^12
limb vectors turn the whole inner loop into 8-lane-friendly u32 FMAs with
*no cross-lane communication* except the final carry sweep, and the batch
dimension maps onto the VPU sublanes.  VMEM budget per program:
3 blocks × TILE_B × (L+1) × 4 B ≈ 0.4 MB at TILE_B=128, L=176 (2048-bit).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.crypto import rns as rns_lib

LIMB_BITS = 12
MASK = (1 << LIMB_BITS) - 1
_U32 = jnp.uint32

DEFAULT_TILE_B = 128


def _montmul_block(a, b, n, n0inv: int, L: int):
    """CIOS Montgomery product on a (TB, L) block.  Shared only by
    kernel bodies (this one and the fused ladders in montexp.py) — it is
    traced inline, so each kernel's IR is still self-contained when it
    ships to Mosaic."""
    TB = a.shape[0]
    t = jnp.zeros((TB, L + 1), _U32)

    def round_fn(i, t):
        ai = jax.lax.dynamic_slice_in_dim(a, i, 1, axis=1)      # (TB, 1)
        t = t.at[:, :L].add(ai * b)
        m = (t[:, 0] * _U32(n0inv)) & MASK
        t = t.at[:, :L].add(m[:, None] * n)
        carry0 = t[:, 0] >> LIMB_BITS
        t = jnp.concatenate([t[:, 1:], jnp.zeros((TB, 1), _U32)], axis=1)
        t = t.at[:, 0].add(carry0)
        # one-shot lazy carry: keeps limbs < 2^16 (exact, value-preserving)
        low = t & MASK
        hi = t >> LIMB_BITS
        return low + jnp.concatenate(
            [jnp.zeros((TB, 1), _U32), hi[:, :-1]], axis=1)

    t = jax.lax.fori_loop(0, L, round_fn, t)

    # exact normalization (sequential carry over L+1 limbs)
    def sweep(i, st):
        t, c = st
        v = t[:, i] + c
        return t.at[:, i].set(v & MASK), v >> LIMB_BITS

    t, _ = jax.lax.fori_loop(0, L + 1, sweep, (t, jnp.zeros((TB,), _U32)))

    # conditional subtract N (t < 2N): compute t - N with borrow, select
    npad = jnp.concatenate([n, jnp.zeros((1, 1), _U32)], axis=1)  # (1, L+1)

    def sub_step(i, st):
        d, borrow = st
        v = t[:, i] + _U32(1 << LIMB_BITS) - npad[0, i] - borrow
        return d.at[:, i].set(v & MASK), _U32(1) - (v >> LIMB_BITS)

    d0 = jnp.zeros_like(t)
    d, borrow = jax.lax.fori_loop(0, L + 1, sub_step,
                                  (d0, jnp.zeros((TB,), _U32)))
    keep_t = (borrow == 1)[:, None]
    return jnp.where(keep_t, t, d)[:, :L]


def _kernel(n0inv: int, L: int, a_ref, b_ref, n_ref, o_ref):
    o_ref[...] = _montmul_block(a_ref[...], b_ref[...], n_ref[...],
                                n0inv, L)


@functools.partial(jax.jit,
                   static_argnames=("n0inv", "L", "tile_b", "interpret"))
def montmul_tiled(a: jnp.ndarray, b: jnp.ndarray, n: jnp.ndarray,
                  *, n0inv: int, L: int, tile_b: int = DEFAULT_TILE_B,
                  interpret: bool = True) -> jnp.ndarray:
    """a, b: (batch, L) canonical limbs (< N); n: (L,).  Returns
    a·b·R^{-1} mod N, canonical.  batch must be a multiple of tile_b
    (ops.py pads)."""
    batch = a.shape[0]
    assert batch % tile_b == 0, "pad batch to a tile multiple in ops.py"
    grid = (batch // tile_b,)
    return pl.pallas_call(
        functools.partial(_kernel, n0inv, L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
            pl.BlockSpec((1, L), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, L), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, L), jnp.uint32),
        interpret=interpret,
    )(a, b, n.reshape(1, L))


# ---------------------------------------------------------------------------
# RNS channel-domain kernel — the compiled pipeline (crypto/rns.py)
# ---------------------------------------------------------------------------
#
# Where the CIOS kernel above runs L sequential carry-coupled rounds, the
# RNS kernel is ONE round of channel-pointwise math plus two exact f32
# matmuls (the base extensions) — the shape the MXU wants.  The body is
# `rns.montmul_channels` traced inline, so the kernel and the jnp library
# path are the same arithmetic by construction.  Conversions limbs ↔
# channels stay outside the kernel (ops.py), amortized across ladder /
# matvec steps.

def _rns_kernel(kA: int, kB: int, ainv_r: int,
                x_ref, y_ref, mods_ref, tb_ref, ta_ref, vecs_ref, o_ref):
    o_ref[...] = rns_lib.montmul_channels(
        x_ref[...], y_ref[...], mods_ref[...], tb_ref[...], ta_ref[...],
        vecs_ref[...], kA=kA, kB=kB, ainv_r=ainv_r)


@functools.partial(jax.jit, static_argnames=("kA", "kB", "ainv_r",
                                             "tile_b", "interpret"))
def rns_montmul_tiled(x: jnp.ndarray, y: jnp.ndarray, mods: jnp.ndarray,
                      t_b: jnp.ndarray, t_a: jnp.ndarray,
                      vecs: jnp.ndarray, *, kA: int, kB: int, ainv_r: int,
                      tile_b: int = DEFAULT_TILE_B,
                      interpret: bool = True) -> jnp.ndarray:
    """x, y: (batch, CH) channel states < (kB+2)·N (y usually entered via
    `rns.to_rns_scaled`).  Returns the channel state of x·y·B⁻¹, same
    bound.  batch must be a multiple of tile_b (ops.py pads)."""
    batch, CH = x.shape
    assert batch % tile_b == 0, "pad batch to a tile multiple in ops.py"
    grid = (batch // tile_b,)
    return pl.pallas_call(
        functools.partial(_rns_kernel, kA, kB, ainv_r),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
            pl.BlockSpec((1, CH), lambda i: (0, 0)),
            pl.BlockSpec(t_b.shape, lambda i: (0, 0)),
            pl.BlockSpec(t_a.shape, lambda i: (0, 0)),
            pl.BlockSpec((6, CH), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, CH), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, CH), jnp.uint32),
        interpret=interpret,
    )(x, y, mods.reshape(1, CH), t_b, t_a, vecs)
