"""jit'd public wrappers around the Pallas kernels: padding, K-chunking,
batch flattening, and drop-in integration points for the crypto layer.

`interpret` defaults to True (this container is CPU); on real TPU pass
interpret=False — the kernels are written against BlockSpec VMEM tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.bigint import Modulus
from repro.crypto.ring import R64
from repro.kernels import montexp as montexp_k
from repro.kernels import montmul as montmul_k
from repro.kernels import ring_matmul as ringmm_k

_U32 = jnp.uint32


def montmul(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus, *,
            tile_b: int = montmul_k.DEFAULT_TILE_B,
            interpret: bool = True) -> jnp.ndarray:
    """Batched Montgomery product via the Pallas kernel.  Accepts any
    leading batch shape; broadcasts a against b; pads to the tile."""
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    bshape = a.shape[:-1]
    L = mod.L
    flat = int(np.prod(bshape)) if bshape else 1
    a2 = a.reshape(flat, L)
    b2 = b.reshape(flat, L)
    pad = (-flat) % tile_b
    if pad:
        a2 = jnp.concatenate([a2, jnp.zeros((pad, L), _U32)], 0)
        b2 = jnp.concatenate([b2, jnp.zeros((pad, L), _U32)], 0)
    out = montmul_k.montmul_tiled(
        a2, b2, jnp.asarray(mod.limbs, _U32),
        n0inv=mod.n0inv, L=L, tile_b=tile_b, interpret=interpret)
    return out[:flat].reshape(bshape + (L,))


def mont_exp_bits(base: jnp.ndarray, bits: jnp.ndarray, mod: Modulus, *,
                  interpret: bool = True) -> jnp.ndarray:
    """Per-step kernel ladder (same contract as bigint.mont_exp_bits):
    2×nbits separate `montmul_tiled` launches — kept as the baseline the
    fused kernel is benchmarked against (kernel_bench)."""
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    base = jnp.broadcast_to(base, bshape + base.shape[-1:])
    bits = jnp.broadcast_to(bits.astype(_U32), bshape + bits.shape[-1:])
    acc0 = jnp.broadcast_to(jnp.asarray(mod.r1, _U32), base.shape)

    def step(acc, bit):
        acc = montmul(acc, acc, mod, interpret=interpret)
        mul = montmul(acc, base, mod, interpret=interpret)
        return jnp.where(bit[..., None] == 1, mul, acc), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    return acc


def mont_exp_fused(base: jnp.ndarray, bits: jnp.ndarray, mod: Modulus, *,
                  tile_b: int = montexp_k.DEFAULT_TILE_B,
                  interpret: bool = True) -> jnp.ndarray:
    """Fused-ladder kernel (same contract as bigint.mont_exp_bits): the
    whole constant-time square-and-multiply loop in ONE pallas_call with
    the accumulator resident in VMEM."""
    base = jnp.asarray(base, _U32)
    bits = jnp.asarray(bits, _U32)
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    L = mod.L
    nbits = bits.shape[-1]
    base = jnp.broadcast_to(base, bshape + (L,))
    bits = jnp.broadcast_to(bits, bshape + (nbits,))
    flat = int(np.prod(bshape)) if bshape else 1
    b2 = base.reshape(flat, L)
    e2 = bits.reshape(flat, nbits)
    tb = min(tile_b, max(flat, 1))
    pad = (-flat) % tb
    if pad:
        b2 = jnp.concatenate([b2, jnp.zeros((pad, L), _U32)], 0)
        e2 = jnp.concatenate([e2, jnp.zeros((pad, nbits), _U32)], 0)
    out = montexp_k.mont_exp_tiled(
        b2, e2, jnp.asarray(mod.limbs, _U32), jnp.asarray(mod.r1, _U32),
        n0inv=mod.n0inv, L=L, tile_b=tb, interpret=interpret)
    return out[:flat].reshape(bshape + (L,))


def he_matvec_fused(cts: jnp.ndarray, digits: jnp.ndarray, mod: Modulus, *,
                    window: int,
                    tile_m: int = montexp_k.DEFAULT_TILE_M,
                    chunk_n: int = montexp_k.DEFAULT_CHUNK_N,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused windowed HE matvec: cts (n, L) Montgomery ciphertexts,
    digits (n, m, levels) MSB-first window digits (the EncodedFeatures
    layout).  Returns (m, L) ciphertexts of Σ_i exps[i,j]·m_i, bit-exact
    vs protocols' windowed library path.  n is chunked to bound the
    in-kernel power table's VMEM footprint; chunk outputs combine with a
    homomorphic ⊕ (an exact group product, so chunking preserves
    bit-exactness)."""
    cts = jnp.asarray(cts, _U32)
    digits = jnp.asarray(digits, _U32)
    n, m, levels = digits.shape
    L = mod.L
    tm = min(tile_m, max(m, 1))
    pad_m = (-m) % tm
    dt = jnp.moveaxis(digits, -1, 0)            # (levels, n, m)
    if pad_m:
        dt = jnp.concatenate(
            [dt, jnp.zeros((levels, n, pad_m), _U32)], axis=-1)
    out = None
    for n0 in range(0, n, chunk_n):
        n1 = min(n, n0 + chunk_n)
        part = montexp_k.he_matvec_tiled(
            cts[n0:n1], dt[:, n0:n1, :], jnp.asarray(mod.limbs, _U32),
            jnp.asarray(mod.r1, _U32), n0inv=mod.n0inv, L=L,
            window=window, tile_m=tm, interpret=interpret)
        out = part if out is None else montmul(out, part, mod,
                                               interpret=interpret)
    return out[:m]


def ring_matmul(a: R64, b: R64, *, tm: int = ringmm_k.DEFAULT_TM,
                tn: int = ringmm_k.DEFAULT_TN,
                interpret: bool = True) -> R64:
    """(M, K) @ (K, N) over Z_2^64 via the limb-MXU kernel.  Pads M/N to
    tiles and chunks K at the exactness bound."""
    M, K = a.lo.shape
    N = b.lo.shape[1]
    padM = (-M) % tm
    padN = (-N) % tn

    def padded(x, pr, pc):
        return jnp.pad(x, ((0, pr), (0, pc)))

    out_hi = jnp.zeros((M + padM, N + padN), _U32)
    out_lo = jnp.zeros_like(out_hi)
    for k0 in range(0, K, ringmm_k.MAX_K_EXACT):
        k1 = min(K, k0 + ringmm_k.MAX_K_EXACT)
        oh, ol = ringmm_k.ring_matmul_tiled(
            padded(a.hi[:, k0:k1], padM, 0), padded(a.lo[:, k0:k1], padM, 0),
            padded(b.hi[k0:k1, :], 0, padN), padded(b.lo[k0:k1, :], 0, padN),
            tm=tm, tn=tn, interpret=interpret)
        new_lo = out_lo + ol
        carry = (new_lo < out_lo).astype(_U32)
        out_hi = out_hi + oh + carry
        out_lo = new_lo
    return R64(out_hi[:M, :N], out_lo[:M, :N])
