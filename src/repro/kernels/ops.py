"""jit'd public wrappers around the Pallas kernels: padding, K-chunking,
batch flattening, and drop-in integration points for the crypto layer.

`interpret` defaults to True (this container is CPU); on real TPU pass
interpret=False — the kernels are written against BlockSpec VMEM tiling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto import rns
from repro.crypto.bigint import Modulus
from repro.crypto.ring import R64
from repro.kernels import montexp as montexp_k
from repro.kernels import montmul as montmul_k
from repro.kernels import ring_matmul as ringmm_k

_U32 = jnp.uint32


def montmul(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus, *,
            tile_b: int = montmul_k.DEFAULT_TILE_B,
            interpret: bool = True) -> jnp.ndarray:
    """Batched Montgomery product via the Pallas kernel.  Accepts any
    leading batch shape; broadcasts a against b; pads to the tile."""
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    bshape = a.shape[:-1]
    L = mod.L
    flat = int(np.prod(bshape)) if bshape else 1
    a2 = a.reshape(flat, L)
    b2 = b.reshape(flat, L)
    pad = (-flat) % tile_b
    if pad:
        a2 = jnp.concatenate([a2, jnp.zeros((pad, L), _U32)], 0)
        b2 = jnp.concatenate([b2, jnp.zeros((pad, L), _U32)], 0)
    out = montmul_k.montmul_tiled(
        a2, b2, jnp.asarray(mod.limbs, _U32),
        n0inv=mod.n0inv, L=L, tile_b=tile_b, interpret=interpret)
    return out[:flat].reshape(bshape + (L,))


def mont_exp_bits(base: jnp.ndarray, bits: jnp.ndarray, mod: Modulus, *,
                  interpret: bool = True) -> jnp.ndarray:
    """Per-step kernel ladder (same contract as bigint.mont_exp_bits):
    2×nbits separate `montmul_tiled` launches — kept as the baseline the
    fused kernel is benchmarked against (kernel_bench)."""
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    base = jnp.broadcast_to(base, bshape + base.shape[-1:])
    bits = jnp.broadcast_to(bits.astype(_U32), bshape + bits.shape[-1:])
    acc0 = jnp.broadcast_to(jnp.asarray(mod.r1, _U32), base.shape)

    def step(acc, bit):
        acc = montmul(acc, acc, mod, interpret=interpret)
        mul = montmul(acc, base, mod, interpret=interpret)
        return jnp.where(bit[..., None] == 1, mul, acc), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    return acc


def mont_exp_fused(base: jnp.ndarray, bits: jnp.ndarray, mod: Modulus, *,
                  tile_b: int = montexp_k.DEFAULT_TILE_B,
                  interpret: bool = True) -> jnp.ndarray:
    """Fused-ladder kernel (same contract as bigint.mont_exp_bits): the
    whole constant-time square-and-multiply loop in ONE pallas_call with
    the accumulator resident in VMEM."""
    base = jnp.asarray(base, _U32)
    bits = jnp.asarray(bits, _U32)
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    L = mod.L
    nbits = bits.shape[-1]
    base = jnp.broadcast_to(base, bshape + (L,))
    bits = jnp.broadcast_to(bits, bshape + (nbits,))
    flat = int(np.prod(bshape)) if bshape else 1
    b2 = base.reshape(flat, L)
    e2 = bits.reshape(flat, nbits)
    tb = min(tile_b, max(flat, 1))
    pad = (-flat) % tb
    if pad:
        b2 = jnp.concatenate([b2, jnp.zeros((pad, L), _U32)], 0)
        e2 = jnp.concatenate([e2, jnp.zeros((pad, nbits), _U32)], 0)
    out = montexp_k.mont_exp_tiled(
        b2, e2, jnp.asarray(mod.limbs, _U32), jnp.asarray(mod.r1, _U32),
        n0inv=mod.n0inv, L=L, tile_b=tb, interpret=interpret)
    return out[:flat].reshape(bshape + (L,))


def he_matvec_fused(cts: jnp.ndarray, digits: jnp.ndarray, mod: Modulus, *,
                    window: int,
                    tile_m: int = montexp_k.DEFAULT_TILE_M,
                    chunk_n: int = montexp_k.DEFAULT_CHUNK_N,
                    interpret: bool = True) -> jnp.ndarray:
    """Fused windowed HE matvec: cts (n, L) Montgomery ciphertexts,
    digits (n, m, levels) MSB-first window digits (the EncodedFeatures
    layout).  Returns (m, L) ciphertexts of Σ_i exps[i,j]·m_i, bit-exact
    vs protocols' windowed library path.  n is chunked to bound the
    in-kernel power table's VMEM footprint; chunk outputs combine with a
    homomorphic ⊕ (an exact group product, so chunking preserves
    bit-exactness)."""
    cts = jnp.asarray(cts, _U32)
    digits = jnp.asarray(digits, _U32)
    n, m, levels = digits.shape
    L = mod.L
    tm = min(tile_m, max(m, 1))
    pad_m = (-m) % tm
    dt = jnp.moveaxis(digits, -1, 0)            # (levels, n, m)
    if pad_m:
        dt = jnp.concatenate(
            [dt, jnp.zeros((levels, n, pad_m), _U32)], axis=-1)
    out = None
    for n0 in range(0, n, chunk_n):
        n1 = min(n, n0 + chunk_n)
        part = montexp_k.he_matvec_tiled(
            cts[n0:n1], dt[:, n0:n1, :], jnp.asarray(mod.limbs, _U32),
            jnp.asarray(mod.r1, _U32), n0inv=mod.n0inv, L=L,
            window=window, tile_m=tm, interpret=interpret)
        out = part if out is None else montmul(out, part, mod,
                                               interpret=interpret)
    return out[:m]


def ring_matmul(a: R64, b: R64, *, tm: int = ringmm_k.DEFAULT_TM,
                tn: int = ringmm_k.DEFAULT_TN,
                interpret: bool = True) -> R64:
    """(M, K) @ (K, N) over Z_2^64 via the limb-MXU kernel.  Pads M/N to
    tiles and chunks K at the exactness bound."""
    M, K = a.lo.shape
    N = b.lo.shape[1]
    padM = (-M) % tm
    padN = (-N) % tn

    def padded(x, pr, pc):
        return jnp.pad(x, ((0, pr), (0, pc)))

    out_hi = jnp.zeros((M + padM, N + padN), _U32)
    out_lo = jnp.zeros_like(out_hi)
    for k0 in range(0, K, ringmm_k.MAX_K_EXACT):
        k1 = min(K, k0 + ringmm_k.MAX_K_EXACT)
        oh, ol = ringmm_k.ring_matmul_tiled(
            padded(a.hi[:, k0:k1], padM, 0), padded(a.lo[:, k0:k1], padM, 0),
            padded(b.hi[k0:k1, :], 0, padN), padded(b.lo[k0:k1, :], 0, padN),
            tm=tm, tn=tn, interpret=interpret)
        new_lo = out_lo + ol
        carry = (new_lo < out_lo).astype(_U32)
        out_hi = out_hi + oh + carry
        out_lo = new_lo
    return R64(out_hi[:M, :N], out_lo[:M, :N])


# ---------------------------------------------------------------------------
# RNS pipeline wrappers (kernels/montmul.py + montexp.py channel kernels)
# ---------------------------------------------------------------------------
#
# Same public contracts as the CIOS wrappers above (canonical radix-2^12
# limbs in, canonical limbs out, bit-exact vs the bigint oracle), but the
# kernel-resident representation is the RNS channel state of
# crypto/rns.py.  Conversions run outside the pallas_call — one exact
# split-f32 matmul each way — so a ladder of 2·nbits rounds or a matvec
# of n·levels rounds pays for them once.

def _rns_parts(ctx):
    return (jnp.asarray(ctx.all_mods, _U32), jnp.asarray(ctx.t_b, _U32),
            jnp.asarray(ctx.t_a, _U32), jnp.asarray(ctx.vecs, _U32))


def _flatten_pad(x, width, tile):
    """(..., width) → ((flat+pad, width), flat).  Zero rows are harmless:
    every RNS op maps 0 → 0 and padded outputs are dropped."""
    bshape = x.shape[:-1]
    flat = int(np.prod(bshape)) if bshape else 1
    x2 = x.reshape(flat, width)
    pad = (-flat) % tile
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, width), x2.dtype)], 0)
    return x2, flat


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("tile_b", "interpret"))
def _rns_montmul_flat(ctx, a2, b2, *, tile_b, interpret):
    mods, t_b, t_a, vecs = _rns_parts(ctx)
    t = montmul_k.rns_montmul_tiled(
        rns.to_rns(ctx, a2), rns.to_rns_scaled(ctx, b2), mods, t_b, t_a,
        vecs, kA=ctx.kA, kB=ctx.kB, ainv_r=ctx.ainv_r, tile_b=tile_b,
        interpret=interpret)
    return rns.from_rns(ctx, t)


def rns_montmul(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus, *,
                tile_b: int = montmul_k.DEFAULT_TILE_B,
                interpret: bool = True) -> jnp.ndarray:
    """Batched Montgomery product via the RNS channel kernel — drop-in
    peer of `montmul` (CIOS) and `bigint.mont_mul`."""
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    bshape = a.shape[:-1]
    ctx = rns.for_modulus(mod)
    flat = int(np.prod(bshape)) if bshape else 1
    tb = min(tile_b, max(flat, 1))
    a2, _ = _flatten_pad(a, mod.L, tb)
    b2, _ = _flatten_pad(b, mod.L, tb)
    out = _rns_montmul_flat(ctx, a2, b2, tile_b=tb, interpret=interpret)
    return out[:flat].reshape(bshape + (mod.L,))


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("tile_b", "interpret"))
def _rns_exp_flat(ctx, b2, e2, *, tile_b, interpret):
    mods, t_b, t_a, vecs = _rns_parts(ctx)
    t = montexp_k.rns_mont_exp_tiled(
        rns.to_rns_scaled(ctx, b2), e2, mods, t_b, t_a, vecs,
        rns.const_rns(ctx, "one"), rns.const_rns(ctx, "exit"),
        kA=ctx.kA, kB=ctx.kB, ainv_r=ctx.ainv_r, tile_b=tile_b,
        interpret=interpret)
    return rns.from_rns(ctx, t)


def rns_mont_exp_fused(base: jnp.ndarray, bits: jnp.ndarray,
                       mod: Modulus, *,
                       tile_b: int = montexp_k.DEFAULT_TILE_B,
                       interpret: bool = True) -> jnp.ndarray:
    """Fused constant-time ladder via the RNS kernel (peer of
    `mont_exp_fused` / `bigint.mont_exp_bits`)."""
    base = jnp.asarray(base, _U32)
    bits = jnp.asarray(bits, _U32)
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    nbits = bits.shape[-1]
    base = jnp.broadcast_to(base, bshape + (mod.L,))
    bits = jnp.broadcast_to(bits, bshape + (nbits,))
    ctx = rns.for_modulus(mod)
    flat = int(np.prod(bshape)) if bshape else 1
    tb = min(tile_b, max(flat, 1))
    b2, _ = _flatten_pad(base, mod.L, tb)
    e2, _ = _flatten_pad(bits, nbits, tb)
    out = _rns_exp_flat(ctx, b2, e2, tile_b=tb, interpret=interpret)
    return out[:flat].reshape(bshape + (mod.L,))


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("window", "tile_m", "chunk_n",
                                    "interpret"))
def _rns_matvec_flat(ctx, cts, dt, *, window, tile_m, chunk_n, interpret):
    mods, t_b, t_a, vecs = _rns_parts(ctx)
    one = rns.const_rns(ctx, "one")
    u = rns.to_rns_scaled(ctx, cts)
    n = u.shape[0]
    acc = None
    for n0 in range(0, n, chunk_n):
        n1 = min(n, n0 + chunk_n)
        part = montexp_k.rns_he_matvec_tiled(
            u[n0:n1], dt[:, n0:n1, :], mods, t_b, t_a, vecs, one,
            kA=ctx.kA, kB=ctx.kB, ainv_r=ctx.ainv_r, window=window,
            tile_m=tile_m, interpret=interpret)
        # chunk-⊕ in the ·B domain: one extra RNS round per chunk
        acc = part if acc is None else rns.rns_montmul(ctx, acc, part)
    out = rns.rns_montmul(ctx, acc, jnp.broadcast_to(
        rns.const_rns(ctx, "exit"), acc.shape))
    return rns.from_rns(ctx, out)


def rns_he_matvec_fused(cts: jnp.ndarray, digits: jnp.ndarray,
                        mod: Modulus, *, window: int,
                        tile_m: int = montexp_k.DEFAULT_TILE_M,
                        chunk_n: int = montexp_k.DEFAULT_CHUNK_N,
                        interpret: bool = True) -> jnp.ndarray:
    """Fused windowed HE matvec via the RNS kernel (peer of
    `he_matvec_fused` / `protocols._he_matvec_windowed`): cts (n, L)
    Montgomery ciphertexts, digits (n, m, levels) MSB-first window
    digits → (m, L) canonical ciphertexts of Σ_i exps[i,j]·m_i."""
    cts = jnp.asarray(cts, _U32)
    digits = jnp.asarray(digits, _U32)
    n, m, levels = digits.shape
    ctx = rns.for_modulus(mod)
    tm = min(tile_m, max(m, 1))
    pad_m = (-m) % tm
    dt = jnp.moveaxis(digits, -1, 0)            # (levels, n, m)
    if pad_m:
        dt = jnp.concatenate(
            [dt, jnp.zeros((levels, n, pad_m), _U32)], axis=-1)
    out = _rns_matvec_flat(ctx, cts, dt, window=window, tile_m=tm,
                           chunk_n=chunk_n, interpret=interpret)
    return out[:m]


@functools.partial(jax.jit, static_argnums=(0,),
                   static_argnames=("window", "tile_b", "interpret"))
def _rns_fixb_flat(ctx, table, d2, *, window, tile_b, interpret):
    mods, t_b, t_a, vecs = _rns_parts(ctx)
    t = montexp_k.rns_fixed_base_tiled(
        table, d2, mods, t_b, t_a, vecs, rns.const_rns(ctx, "one"),
        rns.const_rns(ctx, "exit"), kA=ctx.kA, kB=ctx.kB,
        ainv_r=ctx.ainv_r, window=window, tile_b=tile_b,
        interpret=interpret)
    return rns.from_rns(ctx, t)


def rns_fixed_base_fused(table: jnp.ndarray, digits: jnp.ndarray,
                         mod: Modulus, *, window: int,
                         tile_b: int = montexp_k.DEFAULT_TILE_B,
                         interpret: bool = True) -> jnp.ndarray:
    """Fixed-base windowed exponentiation via the RNS kernel from a
    prepared ·B-domain table (levels, 2^window, CH) — the kernel twin of
    `rns.fixed_base_exp`.  digits: (..., levels) LSB-first base-2^window
    digits; returns (..., L) canonical limbs of h^e·R."""
    digits = jnp.asarray(digits, _U32)
    table = jnp.asarray(table, _U32)
    bshape = digits.shape[:-1]
    levels = digits.shape[-1]
    ctx = rns.for_modulus(mod)
    flat = int(np.prod(bshape)) if bshape else 1
    tb = min(tile_b, max(flat, 1))
    d2, _ = _flatten_pad(digits, levels, tb)
    out = _rns_fixb_flat(ctx, table, d2, window=window, tile_b=tb,
                         interpret=interpret)
    return out[:flat].reshape(bshape + (mod.L,))
