"""jit'd public wrappers around the Pallas kernels: padding, K-chunking,
batch flattening, and drop-in integration points for the crypto layer.

`interpret` defaults to True (this container is CPU); on real TPU pass
interpret=False — the kernels are written against BlockSpec VMEM tiling.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.crypto.bigint import Modulus
from repro.crypto.ring import R64
from repro.kernels import montmul as montmul_k
from repro.kernels import ring_matmul as ringmm_k

_U32 = jnp.uint32


def montmul(a: jnp.ndarray, b: jnp.ndarray, mod: Modulus, *,
            tile_b: int = montmul_k.DEFAULT_TILE_B,
            interpret: bool = True) -> jnp.ndarray:
    """Batched Montgomery product via the Pallas kernel.  Accepts any
    leading batch shape; broadcasts a against b; pads to the tile."""
    a, b = jnp.broadcast_arrays(a.astype(_U32), b.astype(_U32))
    bshape = a.shape[:-1]
    L = mod.L
    flat = int(np.prod(bshape)) if bshape else 1
    a2 = a.reshape(flat, L)
    b2 = b.reshape(flat, L)
    pad = (-flat) % tile_b
    if pad:
        a2 = jnp.concatenate([a2, jnp.zeros((pad, L), _U32)], 0)
        b2 = jnp.concatenate([b2, jnp.zeros((pad, L), _U32)], 0)
    out = montmul_k.montmul_tiled(
        a2, b2, jnp.asarray(mod.limbs, _U32),
        n0inv=mod.n0inv, L=L, tile_b=tile_b, interpret=interpret)
    return out[:flat].reshape(bshape + (L,))


def mont_exp_bits(base: jnp.ndarray, bits: jnp.ndarray, mod: Modulus, *,
                  interpret: bool = True) -> jnp.ndarray:
    """Kernel-backed constant-time ladder (same contract as
    bigint.mont_exp_bits)."""
    bshape = jnp.broadcast_shapes(base.shape[:-1], bits.shape[:-1])
    base = jnp.broadcast_to(base, bshape + base.shape[-1:])
    bits = jnp.broadcast_to(bits.astype(_U32), bshape + bits.shape[-1:])
    acc0 = jnp.broadcast_to(jnp.asarray(mod.r1, _U32), base.shape)

    def step(acc, bit):
        acc = montmul(acc, acc, mod, interpret=interpret)
        mul = montmul(acc, base, mod, interpret=interpret)
        return jnp.where(bit[..., None] == 1, mul, acc), None

    acc, _ = jax.lax.scan(step, acc0, jnp.moveaxis(bits, -1, 0))
    return acc


def ring_matmul(a: R64, b: R64, *, tm: int = ringmm_k.DEFAULT_TM,
                tn: int = ringmm_k.DEFAULT_TN,
                interpret: bool = True) -> R64:
    """(M, K) @ (K, N) over Z_2^64 via the limb-MXU kernel.  Pads M/N to
    tiles and chunks K at the exactness bound."""
    M, K = a.lo.shape
    N = b.lo.shape[1]
    padM = (-M) % tm
    padN = (-N) % tn

    def padded(x, pr, pc):
        return jnp.pad(x, ((0, pr), (0, pc)))

    out_hi = jnp.zeros((M + padM, N + padN), _U32)
    out_lo = jnp.zeros_like(out_hi)
    for k0 in range(0, K, ringmm_k.MAX_K_EXACT):
        k1 = min(K, k0 + ringmm_k.MAX_K_EXACT)
        oh, ol = ringmm_k.ring_matmul_tiled(
            padded(a.hi[:, k0:k1], padM, 0), padded(a.lo[:, k0:k1], padM, 0),
            padded(b.hi[k0:k1, :], 0, padN), padded(b.lo[k0:k1, :], 0, padN),
            tm=tm, tn=tn, interpret=interpret)
        new_lo = out_lo + ol
        carry = (new_lo < out_lo).astype(_U32)
        out_hi = out_hi + oh + carry
        out_lo = new_lo
    return R64(out_hi[:M, :N], out_lo[:M, :N])
